//! serve:: acceptance — the sharded bank-parallel serving subsystem:
//! ≥2 distinct apps served concurrently through `serve::Server`, values
//! matching the single-shard `Coordinator` on the same artifacts, plus
//! admission control (bounded queues, backpressure) and drain semantics.

use std::path::PathBuf;
use std::time::Duration;

use stoch_imc::apps::{ol::Ol, App};
use stoch_imc::coordinator::{BatcherConfig, Coordinator};
use stoch_imc::serve::{Server, ServerConfig};

fn manifest_dir(tag: &str, lines: &str) -> PathBuf {
    // Pin the default backend (see tests/interp_engine.rs for why this
    // is safe in this binary).
    std::env::remove_var("STOCH_IMC_BACKEND");
    let dir = std::env::temp_dir().join(format!("stoch_imc_it_serve_{tag}"));
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.txt"), lines).unwrap();
    dir
}

#[test]
fn two_apps_concurrently_match_single_shard_coordinator() {
    // BL=2048 keeps single-estimate stream noise at σ ≈ 0.011, so the
    // serve-vs-coordinator comparison bound (two independent estimates)
    // sits at ≈6σ·√2 and the closed-form bounds at ≈7σ.
    let dir = manifest_dir("two", "op_multiply 2 8 2048\napp_ol 6 8 2048\n");
    let server = Server::start(&dir, ServerConfig::default()).unwrap();
    // Default config: one bank shard per artifact, distinct shards.
    assert_eq!(server.n_shards(), 2);
    assert_eq!(server.apps(), vec!["app_ol".to_string(), "op_multiply".to_string()]);
    assert_ne!(server.shard_of("op_multiply"), server.shard_of("app_ol"));

    let ol = Ol::default();
    let ol_work = ol.workload(16, 7);
    let pairs: Vec<Vec<f64>> = (0..16).map(|i| vec![(i as f64 + 1.0) / 20.0, 0.7]).collect();

    // Both workloads in flight at once from two caller threads.
    let (mul_out, ol_out) = std::thread::scope(|s| {
        let srv = &server;
        let (pairs, ol_work) = (&pairs, &ol_work);
        let h_mul = s.spawn(move || srv.run_workload("op_multiply", pairs).unwrap());
        let h_ol = s.spawn(move || srv.run_workload("app_ol", ol_work).unwrap());
        (h_mul.join().unwrap(), h_ol.join().unwrap())
    });

    // Single-shard reference path over the same artifact dir.
    let coord = Coordinator::start(&dir, BatcherConfig::default()).unwrap();
    let mul_ref = coord.run_workload("op_multiply", &pairs).unwrap();
    let ol_ref = coord.run_workload("app_ol", &ol_work).unwrap();

    for (i, p) in pairs.iter().enumerate() {
        let exact = p[0] * p[1];
        assert!((mul_out[i] - exact).abs() < 0.08, "serve mul {i}: {} vs {exact}", mul_out[i]);
        assert!(
            (mul_out[i] - mul_ref[i]).abs() < 0.1,
            "mul {i}: serve {} vs coordinator {}",
            mul_out[i],
            mul_ref[i]
        );
    }
    for (i, x) in ol_work.iter().enumerate() {
        let f = ol.float_ref(x);
        assert!((ol_out[i] - f).abs() < 0.1, "serve ol {i}: {} vs float {f}", ol_out[i]);
        assert!(
            (ol_out[i] - ol_ref[i]).abs() < 0.12,
            "ol {i}: serve {} vs coordinator {}",
            ol_out[i],
            ol_ref[i]
        );
    }

    // Per-app metrics live on their shard; the pool aggregates both.
    let m_mul = server.metrics("op_multiply");
    let m_ol = server.metrics("app_ol");
    assert_eq!(m_mul.requests, 16);
    assert_eq!(m_ol.requests, 16);
    let pool = server.pool_metrics();
    assert_eq!(pool.requests, 32);
    assert_eq!(pool.waves, m_mul.waves + m_ol.waves);
    assert!(pool.throughput() > 0.0);
}

#[test]
fn bounded_queue_sheds_load_then_drains() {
    // batch=1 ⇒ every admitted request is its own wave, so the shard is
    // almost always busy executing and a depth-1 admission queue must
    // report backpressure to a fast try_submit loop.
    let dir = manifest_dir("bp", "op_multiply 2 1 8192\n");
    let server = Server::start(
        &dir,
        ServerConfig {
            shards: 1,
            queue_depth: 1,
            batcher: BatcherConfig { batch: 1, max_wait: Duration::from_millis(2) },
            row_threads: 1,
            ..ServerConfig::default()
        },
    )
    .unwrap();

    let mut admitted = Vec::new();
    let mut shed = 0usize;
    for _ in 0..50_000 {
        match server.try_submit("op_multiply", &[0.5, 0.5]) {
            Ok(rx) => admitted.push(rx),
            Err(e) => {
                assert!(format!("{e:#}").contains("full"), "unexpected error: {e:#}");
                shed += 1;
                if shed >= 4 && !admitted.is_empty() {
                    break;
                }
            }
        }
    }
    assert!(shed > 0, "depth-1 queue never reported backpressure");
    assert!(!admitted.is_empty(), "nothing admitted");

    // drain() waits until every admitted request has executed; nothing
    // admitted is ever dropped.
    server.drain().unwrap();
    let n_admitted = admitted.len();
    for rx in admitted {
        let v =
            rx.recv().expect("admitted request answered").expect("answered with a value") as f64;
        assert!((v - 0.25).abs() < 0.05, "got {v}");
    }

    // Admission-control telemetry: every shed was counted per app, the
    // try-only loop never blocked, and every executed wave's close
    // reason was recorded.
    let m = server.metrics("op_multiply");
    assert_eq!(m.shed, shed as u64, "each try_submit rejection counts once");
    assert_eq!(m.backpressure_blocks, 0, "try_submit must never block");
    assert_eq!(m.requests, n_admitted as u64);
    assert_eq!(
        m.waves_full + m.waves_deadline + m.waves_flush,
        m.waves,
        "every wave has exactly one close reason"
    );
    // The flat snapshot exposes the same counters under stable keys.
    let snap = server.snapshot();
    assert_eq!(snap.get("serve_op_multiply_shed_total"), Some(shed as f64));
    assert_eq!(snap.get("serve_pool_shed_total"), Some(shed as f64));
    assert!(snap.get("serve_pool_queue_wait_us_p99").is_some());
    assert!(snap.get("serve_pool_queue_depth_max").is_some());
}

#[test]
fn hashed_routing_serves_all_apps_on_fewer_shards() {
    let dir = manifest_dir(
        "hash",
        "op_multiply 2 4 4096\nop_scaled_add 2 4 4096\nop_square_root 1 4 4096\n",
    );
    let server = Server::start(&dir, ServerConfig { shards: 2, ..Default::default() }).unwrap();
    assert_eq!(server.n_shards(), 2);
    for app in server.apps() {
        let shard = server.shard_of(&app).unwrap();
        assert!(shard < 2, "{app} routed to shard {shard}");
    }
    // Every app still serves correctly wherever it hashed to.
    let mul = server.run_workload("op_multiply", &[vec![0.6, 0.5]]).unwrap();
    assert!((mul[0] - 0.30).abs() < 0.1, "mul got {}", mul[0]);
    let add = server.run_workload("op_scaled_add", &[vec![0.2, 0.6]]).unwrap();
    assert!((add[0] - 0.40).abs() < 0.1, "add got {}", add[0]);
    let sqrt = server.run_workload("op_square_root", &[vec![0.49]]).unwrap();
    assert!((sqrt[0] - 0.7).abs() < 0.12, "sqrt got {}", sqrt[0]);
}

#[test]
fn submit_validation_and_unknown_apps() {
    let dir = manifest_dir("valid", "op_multiply 2 4 1024\n");
    let server = Server::start(&dir, ServerConfig::default()).unwrap();
    assert!(server.submit("op_multiply", &[0.5]).is_err(), "wrong arity");
    assert!(server.submit("nope", &[0.5, 0.5]).is_err(), "unknown app");
    assert!(server.try_submit("nope", &[0.5, 0.5]).is_err(), "unknown app (try)");
    assert_eq!(server.n_inputs("nope"), None);
    assert_eq!(server.shard_of("nope"), None);
    assert_eq!(server.n_inputs("op_multiply"), Some(2));
}

#[test]
fn drop_drains_pending_partial_waves() {
    // Same drain-on-shutdown contract the Coordinator has always had,
    // now provided by the shard pool.
    let dir = manifest_dir("drop", "op_multiply 2 64 2048\n");
    let server = Server::start(
        &dir,
        ServerConfig {
            batcher: BatcherConfig { batch: 64, max_wait: Duration::from_secs(600) },
            ..Default::default()
        },
    )
    .unwrap();
    let rx = server.submit("op_multiply", &[0.6, 0.7]).unwrap();
    drop(server);
    let out =
        rx.recv().expect("pending request answered on shutdown").expect("drained with a value")
            as f64;
    assert!((out - 0.42).abs() < 0.1, "got {out}");
}

#[test]
fn dropped_receiver_does_not_wedge_the_executor() {
    // A client that walks away (drops its Receiver) before the wave
    // executes must not panic the shard or wedge its reply `send`; the
    // executor keeps serving later requests on the same shard.
    let dir = manifest_dir("droprx", "op_multiply 2 4 2048\n");
    let server = Server::start(
        &dir,
        ServerConfig {
            shards: 1,
            batcher: BatcherConfig { batch: 4, max_wait: Duration::from_millis(1) },
            ..Default::default()
        },
    )
    .unwrap();

    // Abandon a full wave's worth of requests before it can close.
    for _ in 0..4 {
        let rx = server.submit("op_multiply", &[0.5, 0.5]).unwrap();
        drop(rx);
    }
    server.drain().unwrap();

    // The shard is still healthy: fresh requests round-trip with values.
    let out = server.run_workload("op_multiply", &[vec![0.6, 0.5]]).unwrap();
    assert!((out[0] - 0.30).abs() < 0.1, "post-abandon request got {}", out[0]);

    let m = server.metrics("op_multiply");
    assert_eq!(m.requests, 5, "abandoned requests still count as served");
    assert_eq!(m.failed_requests, 0, "dropped receivers are not failures");
    assert!(server.dead_shards().is_empty(), "no restarts from dropped receivers");
}

#[test]
fn blocking_admission_counts_accepted_after_block_under_contention() {
    // batch=1 over a depth-1 queue with slow waves: blocking `submit`
    // callers from two threads must park on the semaphore and be counted
    // as AcceptedAfterBlock, while every request still gets a value.
    use stoch_imc::serve::ChaosPlan;
    let dir = manifest_dir("block", "op_multiply 2 1 1024\n");
    let server = Server::start(
        &dir,
        ServerConfig {
            shards: 1,
            queue_depth: 1,
            batcher: BatcherConfig { batch: 1, max_wait: Duration::from_millis(1) },
            row_threads: 1,
            // Latency on every wave keeps the executor busy so the
            // admission queue stays full; no panics injected.
            chaos: Some(ChaosPlan {
                latency_every: 1,
                latency: Duration::from_millis(2),
                ..Default::default()
            }),
            ..ServerConfig::default()
        },
    )
    .unwrap();

    const PER_THREAD: usize = 12;
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..2)
            .map(|_| {
                let srv = &server;
                s.spawn(move || {
                    let mut rxs = Vec::with_capacity(PER_THREAD);
                    for _ in 0..PER_THREAD {
                        rxs.push(srv.submit("op_multiply", &[0.5, 0.5]).unwrap());
                    }
                    for rx in rxs {
                        let v = rx.recv().expect("answered").expect("value") as f64;
                        assert!((v - 0.25).abs() < 0.08, "got {v}");
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    });

    let m = server.metrics("op_multiply");
    assert_eq!(m.requests, 2 * PER_THREAD as u64, "every blocking submit was served");
    assert_eq!(m.shed, 0, "blocking submit never sheds");
    assert!(
        m.backpressure_blocks > 0,
        "two fast producers over a depth-1 queue with 2ms waves must block at least once"
    );
}
