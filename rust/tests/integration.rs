//! Cross-layer integration tests: coordinator → engine backend → values
//! matching the L3 functional models, plus the full Algorithm-1 →
//! subarray-execution → oracle chain on a workload. The coordinator
//! tests run on whichever backend `STOCH_IMC_BACKEND` selects (the
//! interpreter by default, which needs only `manifest.txt`).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use stoch_imc::coordinator::{BatcherConfig, Coordinator};
use stoch_imc::netlist::{eval::eval_stochastic, ops, replicate::replicate};
use stoch_imc::sc::bitstream::Bitstream;
use stoch_imc::scheduler::algorithm1::{schedule, Options};
use stoch_imc::scheduler::validate::validate;
use stoch_imc::util::prng::Xoshiro256;

fn subset_dir(names: &[&str]) -> Option<PathBuf> {
    let src = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !src.join("manifest.txt").exists() {
        return None; // artifacts not built — skip
    }
    let manifest = std::fs::read_to_string(src.join("manifest.txt")).ok()?;
    let dir = std::env::temp_dir().join(format!("stoch_imc_it_{}", names.join("_")));
    std::fs::create_dir_all(&dir).ok()?;
    let mut lines = Vec::new();
    for n in names {
        let line = manifest.lines().find(|l| l.starts_with(n))?;
        lines.push(line.to_string());
        // HLO text is only needed by the PJRT backend; the interpreter
        // works from the manifest alone.
        let hlo = src.join(format!("{n}.hlo.txt"));
        if hlo.exists() {
            std::fs::copy(&hlo, dir.join(format!("{n}.hlo.txt"))).ok()?;
        }
    }
    std::fs::write(dir.join("manifest.txt"), lines.join("\n") + "\n").ok()?;
    Some(dir)
}

#[test]
fn coordinator_ops_match_closed_forms() {
    let Some(dir) = subset_dir(&["op_multiply", "op_scaled_add", "op_scaled_divide"]) else {
        return;
    };
    let coord = Coordinator::start(&dir, BatcherConfig::default()).unwrap();
    let pairs: Vec<Vec<f64>> = vec![
        vec![0.2, 0.9],
        vec![0.5, 0.5],
        vec![0.8, 0.3],
        vec![0.95, 0.95],
    ];
    let mul = coord.run_workload("op_multiply", &pairs).unwrap();
    let add = coord.run_workload("op_scaled_add", &pairs).unwrap();
    let div = coord.run_workload("op_scaled_divide", &pairs).unwrap();
    // Tolerances at the committed manifest's paper-default BL=256: a
    // unipolar SN estimate has σ = sqrt(p(1-p)/BL) ≤ 0.032, so 0.12 is
    // ≈4σ for the combinational ops. The JK feedback divider also pays
    // a convergence transient over the first stream bits, hence its
    // looser 0.20 bound (it was 0.09 when the manifest shipped BL=1024).
    for (i, p) in pairs.iter().enumerate() {
        assert!((mul[i] - p[0] * p[1]).abs() < 0.12, "mul {i}: {}", mul[i]);
        assert!((add[i] - (p[0] + p[1]) / 2.0).abs() < 0.12, "add {i}");
        assert!((div[i] - p[0] / (p[0] + p[1])).abs() < 0.20, "div {i}: {}", div[i]);
    }
    // Batching metrics recorded.
    let m = coord.metrics("op_multiply");
    assert_eq!(m.requests, 4);
    assert!(m.waves >= 1);
}

#[test]
fn schedule_execute_oracle_chain_on_workload() {
    // Algorithm 1 schedule → cell-level subarray execution → functional
    // oracle, for a batch of multiply instances (bit-exact equality).
    let mut rng = Xoshiro256::seeded(0xC0DE);
    let base = ops::multiply();
    let q = 64;
    let rep = replicate(&base, q);
    let sched = schedule(&rep, &Options::default());
    assert!(validate(&rep, &sched, 256, 256).is_empty());
    for case in 0..8 {
        let a = 0.1 + 0.1 * case as f64;
        let mut inputs = HashMap::new();
        inputs.insert("a".to_string(), Bitstream::sample(a, 256, &mut rng));
        inputs.insert("b".to_string(), Bitstream::sample(0.7, 256, &mut rng));
        let mut array = stoch_imc::imc::Subarray::new(q, sched.cols_used);
        let (got, _) = stoch_imc::imc::execute_replicated(
            &base, &rep, &sched, &inputs, q, &mut array, &mut rng,
        );
        let want = eval_stochastic(&base, &inputs);
        assert_eq!(got["out"], want["out"], "case {case}");
    }
}

#[test]
fn app_artifact_matches_l3_functional_model() {
    use stoch_imc::apps::App;
    let Some(dir) = subset_dir(&["app_ol"]) else { return };
    let coord = Coordinator::start(&dir, BatcherConfig::default()).unwrap();
    let app = stoch_imc::apps::ol::Ol::default();
    let w = app.workload(32, 7);
    let outs = coord.run_workload("app_ol", &w).unwrap();
    let mut rng = Xoshiro256::seeded(3);
    for (x, o) in w.iter().zip(&outs) {
        let l3 = app.stoch_value(x, 4096, &mut rng, 0.0);
        let float = app.float_ref(x);
        // Both layers approximate the same function. The engine runs at
        // the committed manifest BL=256 (σ ≈ 0.032 per stream, and 32
        // instances are checked, so the bound sits at ≈5.5σ); the L3
        // reference below runs at BL=4096 and keeps its tight bound.
        assert!((o - float).abs() < 0.18, "engine {o} vs float {float}");
        assert!((l3 - float).abs() < 0.08, "l3 {l3} vs float {float}");
    }
}
