//! Kernel density estimation (paper Fig 9d / Eq 10): per-pixel
//! background PDF over an 8-frame history — foreground pixels (low PDF)
//! are anomalies. Full PJRT path via `app_kde`.
//!
//! Run: cargo run --release --example kernel_density

use stoch_imc::apps::{kde::Kde, App};
use stoch_imc::coordinator::{BatcherConfig, Coordinator};
use stoch_imc::util::stats::mean_error_pct;

fn main() -> stoch_imc::error::Result<()> {
    let app = Kde::default();
    let pixels = app.workload(256, 0xCDE);
    let coord = Coordinator::start(std::path::Path::new("artifacts"), BatcherConfig::default())?;
    let t0 = std::time::Instant::now();
    let pdfs = coord.run_workload("app_kde", &pixels)?;
    let dt = t0.elapsed();
    let refs: Vec<f64> = pixels.iter().map(|x| app.float_ref(x)).collect();
    let err = mean_error_pct(&refs, &pdfs);
    println!(
        "KDE: {} pixel histories in {:.2?} ({:.0}/s), mean PDF error {:.2}%",
        pdfs.len(),
        dt,
        pdfs.len() as f64 / dt.as_secs_f64(),
        err
    );
    // Anomaly detection: flag the lowest-PDF pixels; check they are the
    // ones whose current value jumped away from their history.
    let mut idx: Vec<usize> = (0..pdfs.len()).collect();
    idx.sort_by(|&a, &b| pdfs[a].partial_cmp(&pdfs[b]).unwrap());
    println!("10 most anomalous pixels (lowest background PDF):");
    for &i in idx.iter().take(10) {
        let x = &pixels[i];
        let drift = x[1..].iter().map(|v| (x[0] - v).abs()).sum::<f64>() / 8.0;
        println!("  pixel {i:>3}: pdf={:.3} (ref {:.3}) mean|Δ|={drift:.3}", pdfs[i], refs[i]);
    }
    stoch_imc::ensure!(err < 12.0, "accuracy regression: {err:.2}%");
    println!("kernel_density OK");
    Ok(())
}
