//! End-to-end driver (DESIGN.md E4/LIT): Sauvola local image
//! thresholding of a synthetic degraded document, full three-layer path:
//! Rust coordinator → PJRT (JAX/Pallas artifact `app_lit`) → StoB.
//! Reports per-window accuracy vs the float reference, the binarized
//! image, throughput, and coordinator batching metrics.
//!
//! Run: cargo run --release --example image_thresholding

use stoch_imc::apps::{lit::Lit, App};
use stoch_imc::coordinator::{BatcherConfig, Coordinator};
use stoch_imc::util::stats::mean_error_pct;

fn main() -> stoch_imc::error::Result<()> {
    let app = Lit::default();
    let windows = app.workload(app.eval_instances(), 0x570C41);
    println!(
        "LIT: {} windows of {}×{} from a {}×{} synthetic degraded page",
        windows.len(),
        app.side,
        app.side,
        app.image_side,
        app.image_side
    );

    println!("compiling app_lit PJRT executable (one-time)…");
    let coord = Coordinator::start(std::path::Path::new("artifacts"), BatcherConfig::default())?;
    let t0 = std::time::Instant::now();
    let thresholds = coord.run_workload("app_lit", &windows)?;
    let dt = t0.elapsed();

    let refs: Vec<f64> = windows.iter().map(|w| app.float_ref(w)).collect();
    let err = mean_error_pct(&refs, &thresholds);
    println!(
        "{} windows in {:.2?} ({:.1} windows/s), mean threshold error {:.2}%",
        windows.len(),
        dt,
        windows.len() as f64 / dt.as_secs_f64(),
        err
    );
    println!("coordinator: {}", coord.metrics("app_lit").summary());

    // Binarize and render one strip of the page with the thresholds.
    let tiles = app.image_side / app.side;
    println!("binarized page (first {} window-rows):", tiles.min(4));
    for wy in 0..tiles.min(4) {
        for py in 0..app.side {
            let mut line = String::new();
            for wx in 0..tiles {
                let w = &windows[wy * tiles + wx];
                let t = thresholds[wy * tiles + wx];
                for px in 0..app.side {
                    let v = w[py * app.side + px];
                    line.push(if v < t { '#' } else { '.' });
                }
            }
            println!("{line}");
        }
    }
    stoch_imc::ensure!(err < 20.0, "accuracy regression: {err:.2}%");
    println!("image_thresholding OK");
    Ok(())
}
