//! Bitflip-tolerance demo (paper Table 4): inject faults at operation
//! boundaries and watch binary IMC degrade while Stoch-IMC shrugs.
//! Pure L3 functional models (fault injection needs bit-level access,
//! which the in-graph artifacts deliberately do not expose).
//!
//! Run: cargo run --release --example fault_tolerance

use stoch_imc::apps::{all_apps, output_error_pct};

fn main() {
    let rates = [0.0, 0.05, 0.10, 0.15, 0.20];
    println!("mean output error (%) vs injected bitflip rate");
    println!("{:<6} {:>8} | {}", "app", "method", "0%     5%    10%    15%    20%");
    for app in all_apps() {
        let w = app.workload(16, 99);
        for (label, stochastic) in [("binary", false), ("stoch", true)] {
            let errs: Vec<String> = rates
                .iter()
                .map(|&r| {
                    format!(
                        "{:6.2}",
                        output_error_pct(app.as_ref(), &w, 256, 8, r, stochastic, 0xF417)
                    )
                })
                .collect();
            println!("{:<6} {:>8} | {}", app.name(), label, errs.join(" "));
        }
    }
    println!("\nNote the crossover around 5% (paper §5.3.2): below it the");
    println!("stochastic approximation noise dominates; above it binary's");
    println!("MSB fragility takes over while Stoch-IMC stays below ~7%.");
}
