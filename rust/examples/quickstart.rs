//! Quickstart: the three-layer path in one page.
//!
//! 1. L3 loads the artifact registry (`artifacts/manifest.txt`); the
//!    default backend is the pure-Rust interpreter, while
//!    `STOCH_IMC_BACKEND=pjrt` (xla-runtime feature) runs the AOT HLO
//!    artifacts instead.
//! 2. Requests flow through the coordinator's batcher to the engine.
//! 3. Results come back as binary values (StoB popcount done in-graph).
//!
//! The committed manifest uses the paper-default BL=256 per artifact, so
//! a single stochastic estimate carries σ = sqrt(p(1-p)/256) ≈ 0.03 of
//! stream noise — the tolerances below are ~4σ. See the
//! `multi_app_server` example for the sharded multi-app serving path.
//!
//! Run: cargo run --release --example quickstart

use stoch_imc::coordinator::{BatcherConfig, Coordinator};

fn main() -> stoch_imc::error::Result<()> {
    let coord = Coordinator::start(std::path::Path::new("artifacts"), BatcherConfig::default())?;
    println!("artifacts: {:?}", coord.apps());

    // Stochastic multiplication: 0.6 × 0.7 on a 256-bit stream.
    let out = coord.run_workload("op_multiply", &[vec![0.6, 0.7]])?[0];
    println!("0.6 × 0.7 ≈ {out:.3} (exact 0.42)");
    assert!((out - 0.42).abs() < 0.13);

    // Scaled division a/(a+b) — the JK feedback divider (transient
    // convergence makes it the noisiest op at BL=256).
    let out = coord.run_workload("op_scaled_divide", &[vec![0.3, 0.6]])?[0];
    println!("0.3/(0.3+0.6) ≈ {out:.3} (exact 0.333)");
    assert!((out - 1.0 / 3.0).abs() < 0.2);

    // A batch: the batcher packs these into one subarray-group wave.
    let pairs: Vec<Vec<f64>> = (1..=8).map(|i| vec![i as f64 / 10.0, 0.5]).collect();
    let outs = coord.run_workload("op_multiply", &pairs)?;
    for (p, o) in pairs.iter().zip(&outs) {
        println!("{:.1} × 0.5 ≈ {o:.3}", p[0]);
    }
    println!("quickstart OK — {}", coord.metrics("op_multiply").summary());
    Ok(())
}
