//! Multi-app serving: two applications in flight concurrently through
//! the sharded `serve::Server` — the software model of the paper's
//! bank-level parallelism (each artifact gets its own bank-controller
//! shard; waves execute row-parallel inside each shard).
//!
//! Two caller threads drive OL (Bayesian object location) and HDP
//! (heart-disaster prediction) workloads at the same time; the pool
//! routes each to its own shard, and the pool-wide metrics show both
//! apps' waves overlapping in wall-clock time.
//!
//! Run: cargo run --release --example multi_app_server

use stoch_imc::apps::{hdp::Hdp, ol::Ol, App};
use stoch_imc::serve::{Server, ServerConfig};
use stoch_imc::util::stats::mean_error_pct;

fn main() -> stoch_imc::error::Result<()> {
    let server = Server::start(std::path::Path::new("artifacts"), ServerConfig::default())?;
    println!(
        "{} artifacts over {} shards: {:?}",
        server.apps().len(),
        server.n_shards(),
        server.apps()
    );

    let ol = Ol::default();
    let hdp = Hdp;
    let n = 192;
    let ol_work = ol.workload(n, 7);
    let hdp_work = hdp.workload(n, 11);

    // Both workloads in flight at once, one caller thread per app.
    let t0 = std::time::Instant::now();
    let (ol_out, hdp_out) = std::thread::scope(|s| {
        let server_ref = &server;
        let h_ol = s.spawn(move || server_ref.run_workload("app_ol", &ol_work));
        let h_hdp = s.spawn(move || server_ref.run_workload("app_hdp", &hdp_work));
        (h_ol.join().expect("ol thread"), h_hdp.join().expect("hdp thread"))
    });
    let dt = t0.elapsed();
    let (ol_out, hdp_out) = (ol_out?, hdp_out?);

    let ol_refs: Vec<f64> = ol.workload(n, 7).iter().map(|x| ol.float_ref(x)).collect();
    let hdp_refs: Vec<f64> = hdp.workload(n, 11).iter().map(|x| hdp.float_ref(x)).collect();
    println!(
        "app_ol  (shard {}): {} results, mean err {:.2}% — {}",
        server.shard_of("app_ol").unwrap_or(0),
        ol_out.len(),
        mean_error_pct(&ol_refs, &ol_out),
        server.metrics("app_ol").summary()
    );
    println!(
        "app_hdp (shard {}): {} results, mean err {:.2}% — {}",
        server.shard_of("app_hdp").unwrap_or(0),
        hdp_out.len(),
        mean_error_pct(&hdp_refs, &hdp_out),
        server.metrics("app_hdp").summary()
    );
    println!(
        "pool: {} instances in {dt:.2?} — {}",
        ol_out.len() + hdp_out.len(),
        server.pool_metrics().summary()
    );

    // Backpressure demo: try_submit sheds load instead of blocking when
    // a shard's bounded admission queue is saturated.
    let tiny = Server::start(
        std::path::Path::new("artifacts"),
        ServerConfig { shards: 1, queue_depth: 1, ..ServerConfig::default() },
    )?;
    let mut admitted = 0;
    let mut shed = 0;
    let mut pending = Vec::new();
    for i in 0..512 {
        match tiny.try_submit("op_multiply", &[0.3 + 0.001 * i as f64, 0.5]) {
            Ok(rx) => {
                admitted += 1;
                pending.push(rx);
            }
            Err(_) => shed += 1,
        }
    }
    tiny.drain()?;
    let answered = pending.iter().filter(|rx| matches!(rx.recv(), Ok(Ok(_)))).count();
    println!(
        "admission control (queue_depth=1): {admitted} admitted (all {answered} answered), \
         {shed} shed with backpressure"
    );
    Ok(())
}
