//! Bayesian inference workloads (paper Fig 9b/9c): object location over
//! a 64×64 grid and heart-disaster prediction, both through the PJRT
//! artifacts. Prints the located object cell and a risk table.
//!
//! Run: cargo run --release --example bayesian_inference

use stoch_imc::apps::{hdp::Hdp, ol::Ol, App};
use stoch_imc::coordinator::{BatcherConfig, Coordinator};

fn main() -> stoch_imc::error::Result<()> {
    let coord = Coordinator::start(std::path::Path::new("artifacts"), BatcherConfig::default())?;

    // --- Object location: evaluate p(x,y) over a sub-grid.
    let ol = Ol { grid: 32, sensors: 3 };
    let (grid_points, obj) = ol.grid_workload(0xB0B);
    let t0 = std::time::Instant::now();
    let probs = coord.run_workload("app_ol", &grid_points)?;
    println!(
        "OL: {} grid points in {:.2?}; argmax p = {:.4}",
        probs.len(),
        t0.elapsed(),
        probs.iter().cloned().fold(0.0, f64::max)
    );
    let best = probs
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap()
        .0;
    println!("object located at grid cell ({}, {})", best % 32, best / 32);
    let (bx, by) = (best % 32, best / 32);
    let dist =
        (((bx as f64 - obj.0 as f64).powi(2) + (by as f64 - obj.1 as f64).powi(2)) as f64).sqrt();
    println!("true object at ({}, {}) — distance {dist:.1} cells", obj.0, obj.1);
    stoch_imc::ensure!(dist <= 6.0, "stochastic localization strayed too far");

    // --- Heart-disaster prediction: a batch of patients.
    let hdp = Hdp;
    let patients = hdp.workload(16, 0xCAFE);
    let risks = coord.run_workload("app_hdp", &patients)?;
    println!("\nHDP risk table (stochastic vs float):");
    for (i, (x, r)) in patients.iter().zip(&risks).enumerate() {
        let f = hdp.float_ref(x);
        println!("  patient {i:>2}: P(HD) = {r:.3} (ref {f:.3})");
        stoch_imc::ensure!((r - f).abs() < 0.12, "patient {i} error too large");
    }
    println!("bayesian_inference OK");
    Ok(())
}
